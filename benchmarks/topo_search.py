"""Capex/perf Pareto co-design search over SuperPod geometries (§6.4).

The paper's 2.04x cost-efficiency headline is a *co-design* claim: the
4D-FM+Clos geometry is the right point on a (training step time, TCO)
frontier, not merely a cheaper network.  This search reproduces that
frontier end to end:

1. **Enumerate** — the ``core.codesign.enumerate_geometries`` grid (64
   candidates by default: per-dim lane provisioning x pod uplink width).
2. **Analytic pre-filter** — closed-form TCO (``core.capex``) plus
   vectorized step-time bounds (``planner.analytic_iteration_arrays``)
   cull candidates that provably cannot reach the measured frontier
   (``core.codesign.prefilter_geometries``; winner-safe at margin 5x).
3. **Calibrate** — every survivor gets a netsim-calibrated
   ``NetsimPerfModel``.  ``--mode batched`` (default) prices all of them
   through ``perf_model.precalibrate_models``: measurement signatures
   shared across candidate topologies run in common solver sessions on a
   disjoint host mesh, and structurally identical rack-coarsened pod
   measurements run once.  ``--mode sequential`` is the pre-PR-8 path
   (one ``precalibrate`` per candidate); ``--mode both`` runs both from
   a cold memo and reports the speedup (identical frontier required).
4. **Plan + frontier** — the calibrated planner picks each survivor's
   best parallelization; ``(step time, TCO)`` points go through the
   ``core.codesign.DesignPoint`` dominance relation into the Pareto
   frontier, alongside the switched baselines (Clos(x64T), 2D-FM/1D-FM
   hybrids) priced by ``core.capex`` with the idealized
   ``clos_comm_model`` step time.
5. **Fig. 21 repro** — cost-efficiency vs Clos from the *measured*
   UB-Mesh step time (bar: >= 1.9x) next to the paper-calibrated default
   (2.04x), and the 67% -> 20% network-share collapse.

Run it::

    PYTHONPATH=src python -m benchmarks.topo_search                # 64 @ 8192
    PYTHONPATH=src python -m benchmarks.topo_search --mode both    # + speedup
    PYTHONPATH=src python -m benchmarks.topo_search --smoke --json out.json

``codesign_smoke`` (the ``run.py --suite smoke`` entry) runs the reduced
2-pod / 2048-chip sweep in well under 30 s.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from repro.core.capex import (
    clos_bom,
    compare_architectures,
    hybrid_bom,
    ub_mesh_bom,
)
from repro.core.codesign import (
    DesignPoint,
    GeometryCandidate,
    enumerate_geometries,
    pareto_frontier,
    prefilter_geometries,
)
from repro.core.cost_model import clos_comm_model
from repro.core.perf_model import (
    AnalyticPerfModel,
    precalibrate_models,
)
from repro.core.availability import clos_afr
from repro.core.planner import Prefilter, enumerate_specs, memory_feasible, plan
from repro.core.traffic import backend_comparison_workloads
from repro.runtime.campaign import availability_score, unavailability_for_afr

_CAL_BYTES = 16e6

# the switched baselines are contention-free by construction (non-blocking
# Clos), so their step time is the idealized analytic plan; the paper's
# Fig. 21 relative-performance calibration (flexibility loss the flow sim
# cannot price) carries the hybrids between the two endpoints
_BASELINE_PERF = {
    "2D-FM+x16Clos": 0.97,
    "1D-FM+x16Clos": 0.985,
    "Clos(x64T)": 1.0,
}


def sweep_workload():
    """The dense-70B config: no A2A traffic, so the pre-filter's 5x comm
    margin is conservative for every collective the sweep prices."""
    w, _ = backend_comparison_workloads()
    return w


def reduced_candidates() -> list[GeometryCandidate]:
    """The 16-candidate guard set (same structure as the full grid, 4x
    smaller): still exercises cross-candidate chip-key dedup (xy lanes),
    coarse pod-structure dedup (uplink x z/a lanes) and the cull."""
    return enumerate_geometries(
        x_lanes=(4, 3), y_lanes=(4,), z_lanes=(2, 1), a_lanes=(2, 1),
        uplinks=(256, 64),
    )


def smoke_candidates() -> list[GeometryCandidate]:
    return enumerate_geometries(
        x_lanes=(4, 3), y_lanes=(4,), z_lanes=(2,), a_lanes=(2, 1),
        uplinks=(256, 64),
    )


def _feasible_specs(w, cand, chips):
    return [
        p
        for p in enumerate_specs(w, chips, rack_size=cand.rack_size)
        if memory_feasible(w, p)
    ]


def sweep_geometries(
    w,
    chips: int,
    candidates: "list[GeometryCandidate]",
    *,
    mode: str = "batched",
    size_bytes: float = _CAL_BYTES,
    keep_k: int = 8,
    margin: float = 5.0,
) -> dict:
    """Pre-filter, calibrate (batched or sequential), plan, frontier.

    Returns a dict with the surviving candidates' ``DesignPoint``s, the
    frontier, per-stage wall times and the calibration session stats.
    The caller owns memo/cache hygiene (see ``_cold_sweep``).

    Every candidate is scored on the third dominance axis —
    Monte-Carlo unavailability from its own component-count AFRs
    (``runtime.campaign.availability_score``, sampling-only, seeded) —
    *before* the cull, so the extended ``prefilter_geometries``
    conjunct stays winner-safe: a candidate is only dropped when some
    survivor beats its analytic step/TCO bounds AND its exact
    availability score."""
    t0 = time.perf_counter()
    ua = {c.name: availability_score(c, chips) for c in candidates}
    survivors, culled, bounds = prefilter_geometries(
        w, candidates, chips, margin=margin,
        unavailability=[ua[c.name] for c in candidates],
    )
    prefilter_s = time.perf_counter() - t0

    models = [c.perf_model(chips, size_bytes=size_bytes) for c in survivors]
    specs_by = [_feasible_specs(w, c, chips) for c in survivors]

    t0 = time.perf_counter()
    if mode == "batched":
        cal = precalibrate_models(models, specs_by)
    elif mode == "sequential":
        cal = {"sessions": 0, "session_keys": 0, "disk_hits": 0}
        for m, specs in zip(models, specs_by):
            st = m.precalibrate(specs)
            for k in cal:
                cal[k] += st.get(k, 0)
    else:  # pragma: no cover - guarded by the CLI choices
        raise ValueError(f"unknown mode {mode!r}")
    calibrate_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    points = []
    for cand, m, specs in zip(survivors, models, specs_by):
        rep = plan(
            w, chips, m,
            rack_size=cand.rack_size,
            top_k=1,
            prefilter=Prefilter(keep_k=keep_k, margin=margin),
            precalibrate=False,       # the sweep already front-loaded it
        )
        best = rep[0]
        points.append(
            DesignPoint(
                name=cand.name,
                step_time_s=best.iteration_s,
                tco=cand.bom(chips).tco(),
                unavailability=ua[cand.name],
                meta={
                    "spec": str(best.spec),
                    "candidate": cand,
                    "capex": cand.bom(chips).capex(),
                    "network_share": cand.bom(chips).network_share(),
                },
            )
        )
    plan_s = time.perf_counter() - t0

    return {
        "mode": mode,
        "chips": chips,
        "n_candidates": len(candidates),
        "n_culled": len(culled),
        "culled": [c.name for c in culled],
        "bounds": bounds,
        "points": points,
        "frontier": pareto_frontier(points),
        "prefilter_s": prefilter_s,
        "calibrate_s": calibrate_s,
        "plan_s": plan_s,
        "wall_s": prefilter_s + calibrate_s + plan_s,
        "calibration": cal,
    }


def _cold_sweep(w, chips, candidates, mode, **kw) -> dict:
    """One sweep leg from a cold calibration state: cleared in-process
    memo, zeroed stats, ephemeral disk cache — the process-restart cost a
    real candidate sweep pays (the ``netsim_planner_throughput`` leg
    convention)."""
    import os
    import shutil
    import tempfile

    from repro.core import perf_model as _pm
    from repro.core.perf_model import reset_calibration_stats

    memo_snapshot = dict(_pm._CALIBRATION_CACHE)
    tmp = tempfile.mkdtemp(prefix="topo-search-")
    old_env = os.environ.get("CALIB_CACHE_DIR")
    os.environ["CALIB_CACHE_DIR"] = tmp
    try:
        _pm._CALIBRATION_CACHE.clear()
        _pm._DISK_CACHES.clear()
        reset_calibration_stats()
        return sweep_geometries(w, chips, candidates, mode=mode, **kw)
    finally:
        if old_env is None:
            os.environ.pop("CALIB_CACHE_DIR", None)
        else:
            os.environ["CALIB_CACHE_DIR"] = old_env
        _pm._DISK_CACHES.clear()
        shutil.rmtree(tmp, ignore_errors=True)
        _pm._CALIBRATION_CACHE.clear()
        _pm._CALIBRATION_CACHE.update(memo_snapshot)
        reset_calibration_stats()


def baseline_points(w, chips: int) -> list[DesignPoint]:
    """The switched architectures as frontier points: idealized analytic
    step time (they are non-blocking by construction) scaled by the
    paper's Fig. 21 relative-performance calibration, TCO from the same
    ``core.capex`` BOMs as the UB-Mesh candidates."""
    multi_pod = chips > 1024
    rep = plan(
        w, chips,
        AnalyticPerfModel(clos_comm_model(multi_pod=multi_pod)),
        top_k=1,
    )
    clos_step = rep[0].iteration_s
    boms = [
        hybrid_bom(chips, fm_dims=2, inter_lanes=16),
        hybrid_bom(chips, fm_dims=1, inter_lanes=16),
        clos_bom(chips),
    ]
    # switched-fabric availability axis: all three baselines lean on the
    # optical-heavy Clos profile (Table 6's contrast is UB vs Clos; the
    # hybrids' exact mix sits between, so this flatters no UB candidate)
    base_ua = unavailability_for_afr(clos_afr(chips))
    return [
        DesignPoint(
            name=b.name,
            step_time_s=clos_step / _BASELINE_PERF[b.name],
            tco=b.tco(),
            unavailability=base_ua,
            meta={
                "capex": b.capex(),
                "network_share": b.network_share(),
                "spec": str(rep[0].spec),
            },
        )
        for b in boms
    ]


def fig21_summary(ub_point: DesignPoint, base_points: list[DesignPoint]) -> dict:
    """Measured cost-efficiency vs Clos + the network-share collapse.

    Two CE numbers, deliberately different in kind:

    * ``ce_gain_default`` uses the paper's Fig. 21 relative-performance
      calibration (UB-Mesh 0.95 vs Clos 1.0) — this is the apples-to-
      apples repro of the ~2.04x headline and the number the goldens pin.
    * ``ce_gain_measured`` charges the UB-Mesh winner its full *netsim*
      step time (all contention, detour-routed) while the Clos baseline
      keeps its idealized analytic 450 GB/s-per-axis step — a mixed
      comparison that systematically flatters Clos.  It is reported as a
      conservative *lower bound* with bar >= 1.0: even under that
      handicap UB-Mesh is no worse per TCO unit than the switched
      baseline."""
    clos = next(p for p in base_points if p.name.startswith("Clos"))
    perf = {p.name: clos.step_time_s / p.step_time_s for p in base_points}
    perf["UB-Mesh(4D-FM+Clos)"] = clos.step_time_s / ub_point.step_time_s
    rows = compare_architectures(perf=perf)
    ce = {r.name: r.cost_efficiency for r in rows}
    gain = ce["UB-Mesh(4D-FM+Clos)"] / ce["Clos(x64T)"]
    default_rows = compare_architectures()
    dce = {r.name: r.cost_efficiency for r in default_rows}
    return {
        "ce_gain_measured": gain,
        "ce_gain_measured_ge_1": gain >= 1.0,
        "ce_gain_default": dce["UB-Mesh(4D-FM+Clos)"] / dce["Clos(x64T)"],
        "ub_relative_perf": perf["UB-Mesh(4D-FM+Clos)"],
        "capex_gain": clos.meta["capex"] / ub_point.meta["capex"],
        "network_share_clos": clos.meta["network_share"],
        "network_share_ub": ub_point.meta["network_share"],
    }


def run_search(
    chips: int = 8192,
    *,
    candidates: "list[GeometryCandidate] | None" = None,
    mode: str = "batched",
    keep_k: int = 8,
) -> dict:
    """The full search; ``mode='both'`` adds a sequential leg and the
    cross-topology-batching speedup (identical frontier asserted)."""
    w = sweep_workload()
    cands = candidates if candidates is not None else enumerate_geometries()

    legs = {}
    if mode == "both":
        legs["sequential"] = _cold_sweep(w, chips, cands, "sequential", keep_k=keep_k)
        legs["batched"] = _cold_sweep(w, chips, cands, "batched", keep_k=keep_k)
    else:
        legs[mode] = _cold_sweep(w, chips, cands, mode, keep_k=keep_k)
    sweep = legs.get("batched") or legs[mode]

    base = baseline_points(w, chips)
    # best measured cost-efficiency candidate = the paper's pick
    ub_best = max(sweep["points"], key=lambda p: p.cost_efficiency)
    joint_frontier = pareto_frontier(sweep["points"] + base)
    out = {
        "chips": chips,
        "mode": mode,
        "sweep": sweep,
        "baselines": base,
        "ub_best": ub_best,
        "joint_frontier": joint_frontier,
        "fig21": fig21_summary(ub_best, base),
    }
    if mode == "both":
        seq, bat = legs["sequential"], legs["batched"]
        same_frontier = [p.name for p in seq["frontier"]] == [
            p.name for p in bat["frontier"]
        ]
        same_specs = all(
            a.meta["spec"] == b.meta["spec"]
            for a, b in zip(seq["points"], bat["points"])
        )
        out["sequential"] = seq
        out["speedup"] = seq["wall_s"] / bat["wall_s"]
        out["cal_speedup"] = (
            seq["calibrate_s"] / bat["calibrate_s"]
            if bat["calibrate_s"] > 0 else float("inf")
        )
        out["frontier_identical"] = same_frontier
        out["winner_specs_identical"] = same_specs
    return out


# ---------------------------------------------------------------------------
# run.py smoke entry
# ---------------------------------------------------------------------------


def codesign_smoke():
    """CI smoke (< 30 s): reduced 2-pod / 2048-chip batched sweep.

    Bars: the sweep completes with a non-empty frontier containing both
    the cheapest and the fastest candidate (2-objective frontier
    endpoints are always undominated), the analytic cull never removes a
    measured frontier member, cross-topology batching actually shares
    sessions (keys measured > solver sessions), and the Fig. 21 repro on
    the paper-calibrated defaults stays on its goldens (2.04x CE, 67% ->
    20% network share at 8K chips)."""
    chips = 2048
    w = sweep_workload()
    cands = smoke_candidates()
    sweep = _cold_sweep(w, chips, cands, "batched")
    points, frontier = sweep["points"], sweep["frontier"]
    fnames = {p.name for p in frontier}
    cheapest = min(points, key=lambda p: p.tco)
    fastest = min(points, key=lambda p: p.step_time_s)
    cal = sweep["calibration"]
    rows = compare_architectures()
    ce = {r.name: r.cost_efficiency for r in rows}
    ce_gain = ce["UB-Mesh(4D-FM+Clos)"] / ce["Clos(x64T)"]
    share_ub = ub_mesh_bom(8192).network_share()
    share_clos = clos_bom(8192).network_share()
    derived = {
        "chips": chips,
        "n_candidates": len(cands),
        "n_culled": sweep["n_culled"],
        "culled_on_frontier": len(set(sweep["culled"]) & fnames),
        "cull_winner_safe": not (set(sweep["culled"]) & fnames),
        "n_frontier": len(frontier),
        "frontier_nonempty": len(frontier) > 0,
        "cheapest_on_frontier": cheapest.name in fnames,
        "fastest_on_frontier": fastest.name in fnames,
        "frontier": ";".join(p.name for p in frontier),
        "best_ce": max(points, key=lambda p: p.cost_efficiency).name,
        "cal_sessions": cal.get("sessions", 0),
        "cal_session_keys": cal.get("session_keys", 0),
        "sessions_shared": cal.get("session_keys", 0) > cal.get("sessions", 0),
        "sweep_wall_s": round(sweep["wall_s"], 2),
        "under_30s": sweep["wall_s"] <= 30.0,
        "fig21_ce_gain": round(ce_gain, 3),
        "ce_gain_within_2pct": abs(ce_gain - 2.04) / 2.04 <= 0.02,
        "network_share_clos": round(share_clos, 3),
        "network_share_ub": round(share_ub, 3),
        "availability_axis_scored": all(p.unavailability > 0 for p in points),
        "ub_more_available_than_clos": (
            min(p.unavailability for p in points)
            < unavailability_for_afr(clos_afr(chips))
        ),
    }
    ref = {
        "ce_gain": 2.04,
        "network_share_clos": 0.67,
        "network_share_ub": 0.20,
        "budget_s": 30.0,
    }
    return derived, ref


CODESIGN_BENCHMARKS = {"codesign_smoke": codesign_smoke}


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _point_doc(p: DesignPoint) -> dict:
    return {
        "name": p.name,
        "step_time_s": round(p.step_time_s, 4),
        "tco": round(p.tco, 1),
        "unavailability": round(p.unavailability, 6),
        "cost_efficiency": p.cost_efficiency,
        "spec": p.meta.get("spec"),
        "network_share": round(p.meta["network_share"], 4)
        if "network_share" in p.meta else None,
    }


def main(argv: "list[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--chips", type=int, default=8192)
    ap.add_argument(
        "--mode", choices=("batched", "sequential", "both"), default="batched"
    )
    ap.add_argument(
        "--smoke", action="store_true",
        help="reduced candidate set at 2048 chips (< 30 s)",
    )
    ap.add_argument("--json", metavar="PATH", default=None)
    args = ap.parse_args(argv)

    if args.smoke:
        derived, ref = codesign_smoke()
        doc = {"suite": "codesign_smoke", "derived": derived, "ref": ref}
        for k, v in derived.items():
            print(f"{k}={v}")
        failures = sum(1 for v in derived.values() if v is False)
    else:
        res = run_search(args.chips, mode=args.mode)
        sweep = res["sweep"]
        print(
            f"sweep: {sweep['n_candidates']} candidates @ {args.chips} chips"
            f" | culled {sweep['n_culled']} | prefilter {sweep['prefilter_s']:.2f}s"
            f" calibrate {sweep['calibrate_s']:.2f}s plan {sweep['plan_s']:.2f}s"
        )
        cal = sweep["calibration"]
        print(
            f"calibration: {cal.get('sessions', 0)} sessions / "
            f"{cal.get('session_keys', 0)} keys"
        )
        if args.mode == "both":
            print(
                f"speedup: {res['speedup']:.2f}x overall, "
                f"{res['cal_speedup']:.2f}x calibration "
                f"(frontier identical: {res['frontier_identical']}, "
                f"winner specs identical: {res['winner_specs_identical']})"
            )
        print("\nfrontier (UB-Mesh candidates + switched baselines):")
        for p in res["joint_frontier"]:
            print(
                f"  {p.name:28s} step {p.step_time_s:.4f}s  "
                f"tco {p.tco:12.0f}  ce {p.cost_efficiency:.3e}"
            )
        f21 = res["fig21"]
        print(
            f"\nFig. 21: measured CE lower bound {f21['ce_gain_measured']:.2f}x"
            f" (paper-calibrated default {f21['ce_gain_default']:.2f}x), "
            f"network share {f21['network_share_clos']:.0%} -> "
            f"{f21['network_share_ub']:.0%}"
        )
        doc = {
            "suite": "topo_search",
            "chips": args.chips,
            "mode": args.mode,
            "points": [_point_doc(p) for p in sweep["points"]],
            "frontier": [_point_doc(p) for p in res["joint_frontier"]],
            "fig21": {
                k: v for k, v in f21.items() if v is not None
            },
            "culled": sweep["culled"],
            "wall_s": round(sweep["wall_s"], 2),
        }
        if args.mode == "both":
            doc["speedup"] = round(res["speedup"], 2)
            doc["cal_speedup"] = round(res["cal_speedup"], 2)
            doc["frontier_identical"] = res["frontier_identical"]
            doc["winner_specs_identical"] = res["winner_specs_identical"]
        failures = 0
        if args.mode == "both" and not (
            res["frontier_identical"] and res["winner_specs_identical"]
        ):
            failures += 1
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(doc, fh, indent=2, default=str)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
