"""Latency-calibrated decode-serving benchmark + SLO-vs-QPS sweep CLI.

``serve_decode_smoke`` (the ``run.py --suite smoke`` entry, < 30 s):

* **Closed-form anchors** — the message-level engine on an idle rack
  must match alpha-beta arithmetic: a one-hop p2p costs exactly
  ``size/cap + latency`` and the 8-clique ring AllReduce lands within 2%
  of the fluid model's makespan for the same DAG (uncongested, the two
  models price identical wire time).
* **Incast tail** — the A2A dispatch's measured p99 task latency
  exceeds its p50: ejection-port queueing is visible, which the fluid
  model's flat launch latency cannot represent.
* **SLO divergence** — ``launch.serve.plan_decode`` on a dense-70B
  decode across one 64-chip rack: the bandwidth-priced objective picks
  maximum TP (smallest weight shard to stream) while the
  latency-calibrated SLO search picks a narrower TP x wider DP sharding
  — and the simulated p99 confirms the bandwidth choice misses the SLO
  the SLO choice meets.  The divergence IS the same-run regression
  guard: each bar is recomputed from scratch every run, so a regression
  in the message engine, the latency profile threading, or the serving
  simulator flips a boolean and fails CI without needing a committed
  baseline.

The CLI writes the SLO-vs-QPS JSON CI uploads as an artifact::

    PYTHONPATH=src python -m benchmarks.serving_bench --smoke \
        --json slo_vs_qps.json
    PYTHONPATH=src python -m benchmarks.serving_bench --qps 10 20 30 40
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from repro.core.traffic import WorkloadSpec
from repro.launch.serve import (
    DECODE_MSG_BYTES,
    plan_decode,
    rack_perf_model,
)

# the canonical serving config: dense-70B decode on one 64-chip rack
SERVE_CHIPS = 64
SERVE_QPS = 30.0
SERVE_SLO_S = 0.012
SERVE_BATCH = 8

REF = {
    # the regime claim ("99 Problems" / §2.3): decode messages are
    # latency-bound, so collective cost scales with group width and the
    # bandwidth-optimal sharding is not the SLO-optimal one
    "diverged": True,
}


def serve_workload() -> WorkloadSpec:
    return WorkloadSpec(
        "dense-70B-serve", 80, 8192, 64, 128, 8,
        seq_len=8192, global_batch=512, params_total=7e10,
    )


def serve_decode_smoke():
    from repro.core.cost_model import Routing
    from repro.netsim import NetSim
    from repro.netsim.flows import _wire_structure
    from repro.core.topology import ub_mesh_rack

    t_start = time.perf_counter()
    topo = ub_mesh_rack()
    sim = NetSim(topo, routing=Routing.DETOUR)

    # -- closed-form anchors -------------------------------------------
    size = DECODE_MSG_BYTES
    prof = sim.measure_latency_profile(size, widths={("model", "allreduce"): 8})
    capacity, _ = _wire_structure(topo)
    cap = capacity[(0, 1)]
    p2p_closed = size / cap + sim.latency_s
    p2p = prof.get("model", "p2p").total_s
    p2p_err = abs(p2p - p2p_closed) / p2p_closed

    from repro.netsim.collectives import clique_nodes, ring_allreduce

    ring = ring_allreduce(topo, clique_nodes(topo, 0), size, tag="bench-ring")
    fluid_t = sim.run_dag(ring).makespan_s
    msg_t = prof.get("model", "allreduce").total_s
    ring_err = abs(msg_t - fluid_t) / fluid_t

    a2a = prof.get("model", "all_to_all")

    # -- SLO-driven decode planning ------------------------------------
    w = serve_workload()
    perf = rack_perf_model()
    res = plan_decode(
        w, SERVE_CHIPS, perf,
        qps=SERVE_QPS, slo_s=SERVE_SLO_S, batch=SERVE_BATCH,
        duration_s=10.0,
    )
    bw, slo = res["bandwidth_choice"], res["slo_choice"]

    wall = time.perf_counter() - t_start
    derived = {
        "p2p_us": round(p2p * 1e6, 3),
        "p2p_closed_us": round(p2p_closed * 1e6, 3),
        "p2p_within_2pct": p2p_err <= 0.02,
        "ring_allreduce_us": round(msg_t * 1e6, 3),
        "ring_fluid_us": round(fluid_t * 1e6, 3),
        "ring_within_2pct_of_fluid": ring_err <= 0.02,
        "a2a_p50_us": round(a2a.p50_s * 1e6, 3),
        "a2a_p99_us": round(a2a.p99_s * 1e6, 3),
        "a2a_tail_visible": a2a.p99_s > a2a.p50_s,
        "bw_choice_tp": bw["tp"],
        "slo_choice_tp": slo["tp"],
        "bw_choice_p99_ms": round(bw["p99_s"] * 1e3, 2),
        "slo_choice_p99_ms": round(slo["p99_s"] * 1e3, 2),
        "slo_choice_tokens_per_s": round(slo["tokens_per_s"], 1),
        "diverged": res["diverged"],
        "slo_choice_meets_slo": slo["meets_slo"],
        "bw_choice_misses_slo": not bw["meets_slo"],
        "wall_s": round(wall, 2),
        "under_30s": wall <= 30.0,
    }
    return derived, dict(REF)


SERVING_BENCHMARKS = {"serve_decode_smoke": serve_decode_smoke}


# ---------------------------------------------------------------------------
# CLI: SLO-vs-QPS sweep (the CI artifact)
# ---------------------------------------------------------------------------


def slo_vs_qps(
    qps_grid: "tuple[float, ...]",
    *,
    chips: int = SERVE_CHIPS,
    slo_s: float = SERVE_SLO_S,
    batch: int = SERVE_BATCH,
    duration_s: float = 10.0,
) -> dict:
    """``plan_decode`` at each target QPS: how the SLO-driven sharding
    and its headroom move as load grows (the bandwidth choice never
    moves — that is the point)."""
    w = serve_workload()
    perf = rack_perf_model()
    points = []
    for qps in qps_grid:
        r = plan_decode(
            w, chips, perf, qps=qps, slo_s=slo_s, batch=batch,
            duration_s=duration_s,
        )
        bw, slo = r["bandwidth_choice"], r["slo_choice"]
        points.append({
            "qps": qps,
            "bw_tp": bw["tp"],
            "bw_p99_s": bw["p99_s"],
            "slo_tp": slo["tp"],
            "slo_p99_s": slo["p99_s"],
            "slo_tokens_per_s": slo["tokens_per_s"],
            "slo_attainment": slo["attainment"],
            "diverged": r["diverged"],
        })
    return {
        "suite": "slo_vs_qps",
        "workload": w.name,
        "chips": chips,
        "slo_s": slo_s,
        "batch": batch,
        "points": points,
    }


def main(argv: "list[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument(
        "--smoke", action="store_true",
        help="the < 30 s CI entry (closed-form anchors + SLO divergence)",
    )
    ap.add_argument(
        "--qps", type=float, nargs="+", default=(10.0, 20.0, 30.0, 40.0),
        help="target request rates for the SLO-vs-QPS sweep",
    )
    ap.add_argument("--chips", type=int, default=SERVE_CHIPS)
    ap.add_argument("--slo-ms", type=float, default=SERVE_SLO_S * 1e3)
    ap.add_argument("--json", metavar="PATH", default=None)
    args = ap.parse_args(argv)

    failures = 0
    doc: dict = {}
    if args.smoke:
        derived, ref = serve_decode_smoke()
        for k, v in derived.items():
            print(f"{k}={v}")
        doc = {"suite": "serve_decode_smoke", "derived": derived, "ref": ref}
        failures = sum(1 for v in derived.values() if v is False)
    sweep = slo_vs_qps(
        tuple(args.qps), chips=args.chips, slo_s=args.slo_ms / 1e3
    )
    for pt in sweep["points"]:
        print(
            f"qps={pt['qps']:g} slo_tp={pt['slo_tp']} "
            f"p99={pt['slo_p99_s']*1e3:.2f}ms "
            f"tok/s={pt['slo_tokens_per_s']:.0f} "
            f"attainment={pt['slo_attainment']:.3f} "
            f"diverged={pt['diverged']}"
        )
    doc = {**doc, **sweep} if doc else sweep
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(doc, fh, indent=2, default=str)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
