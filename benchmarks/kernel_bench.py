"""Kernel micro-benchmarks (interpret mode on CPU — correctness-path wall
time only; TPU perf comes from the roofline analysis, not these timings)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def kernel_benchmarks() -> list[str]:
    from repro.kernels import ops

    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)
    rows = []

    B, K, G, S, D = 1, 2, 2, 256, 64
    q = jax.random.normal(ks[0], (B, K, G, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, K, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, K, S, D), jnp.float32)
    us = _time(lambda a, b, c: ops.flash_attention_bkgsd(a, b, c), q, k, v)
    flops = 4 * B * K * G * S * S * D
    rows.append(f"kernel_flash_attention,{us:.0f},shape=({B}x{K}x{G}x{S}x{D})|flops={flops:.2e}")

    B, S, H, P, N = 1, 256, 4, 32, 16
    xh = jax.random.normal(ks[3], (B, S, H, P))
    ll = -jax.nn.softplus(jax.random.normal(ks[4], (B, S, H)))
    Bm = jax.random.normal(ks[5], (B, S, N))
    Cm = jax.random.normal(ks[6], (B, S, N))
    us = _time(lambda *a: ops.ssd_scan(*a)[0], xh, ll, Bm, Cm)
    rows.append(f"kernel_ssd_scan,{us:.0f},shape=({B}x{S}x{H}x{P}x{N})")

    B, S, H, N = 1, 128, 2, 32
    r = jax.random.normal(ks[0], (B, S, H, N))
    kk = jax.random.normal(ks[1], (B, S, H, N))
    vv = jax.random.normal(ks[2], (B, S, H, N))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, N))) * 0.9 + 0.05
    u = jax.random.normal(ks[4], (H, N)) * 0.3
    us = _time(lambda *a: ops.rwkv6_scan(*a)[0], r, kk, vv, w, u)
    rows.append(f"kernel_rwkv6_scan,{us:.0f},shape=({B}x{S}x{H}x{N})")

    T, E, C, D2 = 256, 4, 64, 64
    disp = jax.nn.one_hot(jax.random.randint(ks[5], (T,), 0, E), E)[:, :, None] * (
        jax.nn.one_hot(jnp.arange(T) % C, C)[:, None, :]
    )
    x = jax.random.normal(ks[6], (T, D2))
    us = _time(lambda a, b: ops.moe_dispatch(a, b), disp.astype(jnp.float32), x)
    rows.append(f"kernel_moe_dispatch,{us:.0f},shape=({T}x{E}x{C})")

    bufs = jax.random.normal(ks[7], (8, 4096))
    us = _time(lambda a: ops.ccu_reduce(a), bufs)
    rows.append(f"kernel_ccu_reduce,{us:.0f},shape=(8x4096)")
    return rows
