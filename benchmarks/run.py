"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run                 # full suite
    PYTHONPATH=src python -m benchmarks.run --suite smoke   # <30 s netsim CI
    PYTHONPATH=src python -m benchmarks.run --suite smoke --json out.json
    PYTHONPATH=src python -m benchmarks.run --suite scale \
        --json BENCH_netsim.json --baseline BENCH_netsim.json

Prints ``name,us_per_call,derived`` CSV; `derived` is `key=value|...` pairs
of computed numbers with the paper's reference values interleaved as
`ref:key=value` for direct comparison.  ``--json PATH`` additionally writes
the structured results (suite, per-benchmark derived/ref dicts, wall time,
errors) to a file — CI uploads it as a workflow artifact so the perf
trajectory is inspectable per PR.  Kernel micro-benchmarks (interpret mode
— CPU wall time, NOT TPU perf) are included for completeness.

The ``smoke`` suite runs tiny flow-level netsim scenarios (cross-validation
vs the analytic model, Fig. 19 routing-strategy ordering, link-failure
recovery, the A2A-vs-AllReduce calibration crossval) plus the
planner-backend comparison (analytic vs netsim-calibrated spec rankings
incl. the AllReduce-proxy vs CalibrationProfile flip, < 10 s) so
network-simulator and planner regressions are caught by default.

The ``scale`` suite (``benchmarks/netsim_scale.py``) records the netsim
perf trajectory: the pod-level calibration speedup (vectorized solver +
symmetric aggregation vs the reference configuration), the rack-coarsened
multi-pod calibration accuracy, and the 4096-chip coarsened plan budget.
``--baseline PATH`` compares the run against a committed
``BENCH_netsim.json`` and exits non-zero when a guarded metric (e.g. the
calibration speedup, a same-run ratio that transfers across machine
speeds) regresses more than ``--regression-threshold``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _write_traces(trace_dir: str) -> list[str]:
    """Run the canonical trunk-congestion scenario under each §4.1 routing
    strategy with telemetry on and drop one Perfetto trace per strategy
    into ``trace_dir`` (CI uploads the directory as an artifact; open the
    files in https://ui.perfetto.dev)."""
    from repro.core.cost_model import Routing
    from repro.netsim import NetSim, trunk_congestion

    os.makedirs(trace_dir, exist_ok=True)
    sc = trunk_congestion()
    written = []
    for pol in (Routing.SHORTEST, Routing.DETOUR, Routing.BORROW):
        sim = NetSim(
            sc.topo, routing=pol, rx_gbs=sc.rx_gbs, telemetry=True
        )
        res = sim.run_dag(sc.dag)
        path = os.path.join(trace_dir, f"trace_{pol.value}.json")
        res.telemetry.to_perfetto(path)
        written.append(path)
    return written


def _fmt(d: dict) -> str:
    return "|".join(f"{k}={v}" for k, v in d.items())


def _check_regressions(
    records: list[dict], baseline_path: str, threshold: float
) -> list[str]:
    """Compare guarded metrics against a committed baseline JSON."""
    from benchmarks.netsim_scale import REGRESSION_GUARDS

    with open(baseline_path) as fh:
        base = {
            r["name"]: r.get("derived", {})
            for r in json.load(fh).get("benchmarks", [])
        }
    new = {r["name"]: r.get("derived", {}) for r in records}
    problems = []
    for bench, key, direction in REGRESSION_GUARDS:
        if bench not in base or key not in base[bench]:
            continue                      # baseline predates this guard
        old_v = float(base[bench][key])
        if bench not in new or key not in new[bench]:
            problems.append(f"{bench}.{key}: missing from this run")
            continue
        new_v = float(new[bench][key])
        if direction == "higher":
            ok = new_v >= old_v * (1 - threshold)
        else:
            ok = new_v <= old_v * (1 + threshold) + 1e-6
        if not ok:
            problems.append(
                f"{bench}.{key}: {new_v:g} vs baseline {old_v:g} "
                f"(>{threshold:.0%} regression, direction={direction})"
            )
    return problems


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", choices=("full", "smoke", "scale"), default="full")
    ap.add_argument(
        "--json",
        metavar="PATH",
        default=None,
        help="also write structured results to PATH (CI artifact)",
    )
    ap.add_argument(
        "--baseline",
        metavar="PATH",
        default=None,
        help="committed benchmark JSON to guard regressions against "
        "(scale suite)",
    )
    ap.add_argument(
        "--regression-threshold",
        type=float,
        default=0.25,
        help="allowed relative regression on guarded metrics (default 25%%)",
    )
    ap.add_argument(
        "--trace-dir",
        metavar="DIR",
        default=None,
        help="also write Perfetto traces of the trunk-congestion scenario "
        "(one per routing strategy) into DIR",
    )
    args = ap.parse_args()

    rows = []
    records: list[dict] = []
    failures = 0
    try:
        from benchmarks.netsim_bench import NETSIM_BENCHMARKS, SMOKE_BENCHMARKS
    except Exception as e:  # noqa: BLE001 - report as a row, don't kill suite
        failures += 1
        rows.append(f"netsim_bench,0,ERROR={type(e).__name__}:{e}")
        NETSIM_BENCHMARKS, SMOKE_BENCHMARKS = {}, {}
    try:
        from benchmarks.planner_bench import PLANNER_BENCHMARKS
    except Exception as e:  # noqa: BLE001
        failures += 1
        rows.append(f"planner_bench,0,ERROR={type(e).__name__}:{e}")
        PLANNER_BENCHMARKS = {}
    try:
        from benchmarks.topo_search import CODESIGN_BENCHMARKS
    except Exception as e:  # noqa: BLE001
        failures += 1
        rows.append(f"topo_search,0,ERROR={type(e).__name__}:{e}")
        CODESIGN_BENCHMARKS = {}
    try:
        from benchmarks.availability_bench import AVAILABILITY_BENCHMARKS
    except Exception as e:  # noqa: BLE001
        failures += 1
        rows.append(f"availability_bench,0,ERROR={type(e).__name__}:{e}")
        AVAILABILITY_BENCHMARKS = {}
    try:
        from benchmarks.serving_bench import SERVING_BENCHMARKS
    except Exception as e:  # noqa: BLE001
        failures += 1
        rows.append(f"serving_bench,0,ERROR={type(e).__name__}:{e}")
        SERVING_BENCHMARKS = {}

    if args.suite == "smoke":
        benchmarks = {
            **SMOKE_BENCHMARKS,
            **PLANNER_BENCHMARKS,
            **CODESIGN_BENCHMARKS,
            **AVAILABILITY_BENCHMARKS,
            **SERVING_BENCHMARKS,
        }
    elif args.suite == "scale":
        from benchmarks.netsim_scale import SCALE_BENCHMARKS

        benchmarks = SCALE_BENCHMARKS
    else:
        from benchmarks.paper_tables import ALL_BENCHMARKS

        benchmarks = {
            **ALL_BENCHMARKS,
            **NETSIM_BENCHMARKS,
            **PLANNER_BENCHMARKS,
            **CODESIGN_BENCHMARKS,
            **AVAILABILITY_BENCHMARKS,
            **SERVING_BENCHMARKS,
        }
    for name, fn in benchmarks.items():
        t0 = time.perf_counter()
        try:
            derived, ref = fn()
            us = (time.perf_counter() - t0) * 1e6
            payload = _fmt(derived)
            if ref:
                payload += "|" + _fmt({f"ref:{k}": v for k, v in ref.items()})
            rows.append(f"{name},{us:.0f},{payload}")
            records.append(
                {"name": name, "us_per_call": round(us), "derived": derived, "ref": ref}
            )
        except Exception as e:  # noqa: BLE001
            failures += 1
            rows.append(f"{name},0,ERROR={type(e).__name__}:{e}")
            records.append(
                {"name": name, "error": f"{type(e).__name__}: {e}"}
            )
    # kernel micro-benches (interpret mode; full suite only)
    if args.suite == "full":
        try:
            from benchmarks.kernel_bench import kernel_benchmarks

            kernel_rows = kernel_benchmarks()
            rows.extend(kernel_rows)
            for row in kernel_rows:
                name, us, payload = row.split(",", 2)
                derived = dict(
                    kv.split("=", 1) for kv in payload.split("|") if "=" in kv
                )
                records.append(
                    {"name": name, "us_per_call": float(us), "derived": derived}
                )
        except Exception as e:  # noqa: BLE001
            failures += 1
            rows.append(f"kernel_bench,0,ERROR={type(e).__name__}:{e}")
            records.append(
                {"name": "kernel_bench", "error": f"{type(e).__name__}: {e}"}
            )

    print("name,us_per_call,derived")
    for r in rows:
        print(r)
    if args.trace_dir:
        try:
            for path in _write_traces(args.trace_dir):
                print(f"trace: {path}", file=sys.stderr)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(
                f"trace export failed: {type(e).__name__}: {e}",
                file=sys.stderr,
            )
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(
                {
                    "suite": args.suite,
                    "failures": failures,
                    "benchmarks": records,
                },
                fh,
                indent=2,
                default=str,
            )
    if args.suite == "scale":
        # the scale benchmarks emit their acceptance bars as booleans
        # (speedup_ge_5x, pod_within_20pct, under_60s, ...); a False bar
        # fails the suite even without a --baseline to diff against
        for rec in records:
            for k, v in rec.get("derived", {}).items():
                if v is False:
                    print(
                        f"BAR FAILED: {rec['name']}.{k} is False",
                        file=sys.stderr,
                    )
                    failures += 1
    if args.baseline:
        problems = _check_regressions(
            records, args.baseline, args.regression_threshold
        )
        for p in problems:
            print(f"REGRESSION: {p}", file=sys.stderr)
        failures += len(problems)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
