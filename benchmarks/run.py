"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run                 # full suite
    PYTHONPATH=src python -m benchmarks.run --suite smoke   # <30 s netsim CI
    PYTHONPATH=src python -m benchmarks.run --suite smoke --json out.json

Prints ``name,us_per_call,derived`` CSV; `derived` is `key=value|...` pairs
of computed numbers with the paper's reference values interleaved as
`ref:key=value` for direct comparison.  ``--json PATH`` additionally writes
the structured results (suite, per-benchmark derived/ref dicts, wall time,
errors) to a file — CI uploads it as a workflow artifact so the perf
trajectory is inspectable per PR.  Kernel micro-benchmarks (interpret mode
— CPU wall time, NOT TPU perf) are included for completeness.

The ``smoke`` suite runs tiny flow-level netsim scenarios (cross-validation
vs the analytic model, Fig. 19 routing-strategy ordering, link-failure
recovery, the A2A-vs-AllReduce calibration crossval) plus the
planner-backend comparison (analytic vs netsim-calibrated spec rankings
incl. the AllReduce-proxy vs CalibrationProfile flip, < 10 s) so
network-simulator and planner regressions are caught by default.
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def _fmt(d: dict) -> str:
    return "|".join(f"{k}={v}" for k, v in d.items())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", choices=("full", "smoke"), default="full")
    ap.add_argument(
        "--json",
        metavar="PATH",
        default=None,
        help="also write structured results to PATH (CI artifact)",
    )
    args = ap.parse_args()

    rows = []
    records: list[dict] = []
    failures = 0
    try:
        from benchmarks.netsim_bench import NETSIM_BENCHMARKS, SMOKE_BENCHMARKS
    except Exception as e:  # noqa: BLE001 - report as a row, don't kill suite
        failures += 1
        rows.append(f"netsim_bench,0,ERROR={type(e).__name__}:{e}")
        NETSIM_BENCHMARKS, SMOKE_BENCHMARKS = {}, {}
    try:
        from benchmarks.planner_bench import PLANNER_BENCHMARKS
    except Exception as e:  # noqa: BLE001
        failures += 1
        rows.append(f"planner_bench,0,ERROR={type(e).__name__}:{e}")
        PLANNER_BENCHMARKS = {}

    if args.suite == "smoke":
        benchmarks = {**SMOKE_BENCHMARKS, **PLANNER_BENCHMARKS}
    else:
        from benchmarks.paper_tables import ALL_BENCHMARKS

        benchmarks = {**ALL_BENCHMARKS, **NETSIM_BENCHMARKS, **PLANNER_BENCHMARKS}
    for name, fn in benchmarks.items():
        t0 = time.perf_counter()
        try:
            derived, ref = fn()
            us = (time.perf_counter() - t0) * 1e6
            payload = _fmt(derived)
            if ref:
                payload += "|" + _fmt({f"ref:{k}": v for k, v in ref.items()})
            rows.append(f"{name},{us:.0f},{payload}")
            records.append(
                {"name": name, "us_per_call": round(us), "derived": derived, "ref": ref}
            )
        except Exception as e:  # noqa: BLE001
            failures += 1
            rows.append(f"{name},0,ERROR={type(e).__name__}:{e}")
            records.append(
                {"name": name, "error": f"{type(e).__name__}: {e}"}
            )
    # kernel micro-benches (interpret mode; full suite only)
    if args.suite == "full":
        try:
            from benchmarks.kernel_bench import kernel_benchmarks

            kernel_rows = kernel_benchmarks()
            rows.extend(kernel_rows)
            for row in kernel_rows:
                name, us, payload = row.split(",", 2)
                derived = dict(
                    kv.split("=", 1) for kv in payload.split("|") if "=" in kv
                )
                records.append(
                    {"name": name, "us_per_call": float(us), "derived": derived}
                )
        except Exception as e:  # noqa: BLE001
            failures += 1
            rows.append(f"kernel_bench,0,ERROR={type(e).__name__}:{e}")
            records.append(
                {"name": "kernel_bench", "error": f"{type(e).__name__}: {e}"}
            )

    print("name,us_per_call,derived")
    for r in rows:
        print(r)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(
                {
                    "suite": args.suite,
                    "failures": failures,
                    "benchmarks": records,
                },
                fh,
                indent=2,
                default=str,
            )
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
