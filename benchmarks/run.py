"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run

Prints ``name,us_per_call,derived`` CSV; `derived` is `key=value|...` pairs
of computed numbers with the paper's reference values interleaved as
`ref:key=value` for direct comparison.  Kernel micro-benchmarks (interpret
mode — CPU wall time, NOT TPU perf) are included for completeness.
"""

from __future__ import annotations

import sys
import time


def _fmt(d: dict) -> str:
    return "|".join(f"{k}={v}" for k, v in d.items())


def main() -> None:
    from benchmarks.paper_tables import ALL_BENCHMARKS

    rows = []
    failures = 0
    for name, fn in ALL_BENCHMARKS.items():
        t0 = time.perf_counter()
        try:
            derived, ref = fn()
            us = (time.perf_counter() - t0) * 1e6
            payload = _fmt(derived)
            if ref:
                payload += "|" + _fmt({f"ref:{k}": v for k, v in ref.items()})
            rows.append(f"{name},{us:.0f},{payload}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            rows.append(f"{name},0,ERROR={type(e).__name__}:{e}")
    # kernel micro-benches (interpret mode)
    try:
        from benchmarks.kernel_bench import kernel_benchmarks

        rows.extend(kernel_benchmarks())
    except Exception as e:  # noqa: BLE001
        failures += 1
        rows.append(f"kernel_bench,0,ERROR={type(e).__name__}:{e}")

    print("name,us_per_call,derived")
    for r in rows:
        print(r)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
