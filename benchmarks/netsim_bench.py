"""Netsim benchmarks: cross-validation against the analytic engine.

Three claims, each one function (same (derived, ref) contract as
``paper_tables.py``):

* **crossval** — on an uncongested single-dimension clique the flow-level
  simulator must reproduce the analytic multi-ring AllReduce time within
  15% (it is the same schedule, executed instead of priced).
* **fig19** — under cross-rack contention the §6.3 routing strategies must
  rank Shortest < Detour < Borrow in delivered throughput (the Fig. 19
  ordering), which only a contention-aware model can show.
* **calibration** — netsim-measured effective axis bandwidths fed back
  into ``core/simulator.simulate`` through the ``PerfModel`` protocol
  (``AnalyticPerfModel`` carrying the measured overrides; the closed-form
  model is optimistic and the calibration quantifies by how much).

``SMOKE_BENCHMARKS`` is the <30 s subset run by ``run.py --suite smoke``.
"""

from __future__ import annotations

from repro.core.cost_model import Routing, build_comm_model
from repro.core.multiring import plan_multiring
from repro.core.simulator import simulate
from repro.core.topology import ub_mesh_pod, ub_mesh_rack
from repro.core.traffic import moe_2t_workload
from repro.netsim import NetSim, hotspot_dag, inter_rack_mesh
from repro.netsim.collectives import clique_nodes, ring_allreduce


# ---------------------------------------------------------------------------
# benchmarks
# ---------------------------------------------------------------------------


def netsim_crossval():
    """Netsim vs analytic multi-ring AllReduce on uncongested cliques."""
    derived = {}
    worst = 0.0
    size = 64e6
    cases = [
        ("rack-X8", ub_mesh_rack(), 0),       # even n=8: zig-zag chains
        ("pod-Z4", ub_mesh_pod(), 2),         # even n=4, inter-rack lanes
    ]
    for label, topo, dim in cases:
        sim = NetSim(topo, routing=Routing.DETOUR)
        t = sim.allreduce_time(dim, size)
        ta = plan_multiring(topo, dim).allreduce_time_s(size)
        rel = abs(t - ta) / ta
        worst = max(worst, rel)
        derived[f"{label}_netsim_ms"] = round(t * 1e3, 4)
        derived[f"{label}_analytic_ms"] = round(ta * 1e3, 4)
        derived[f"{label}_rel_err"] = round(rel, 4)
    derived["within_15pct"] = worst <= 0.15
    ref = {"tolerance": 0.15}
    return derived, ref


def netsim_fig19():
    """Shortest < Detour < Borrow throughput under cross-rack contention."""
    topo = inter_rack_mesh()
    dag = hotspot_dag(topo)
    total = sum(t.size for t in dag.tasks)
    tput = {}
    for pol in (Routing.SHORTEST, Routing.DETOUR, Routing.BORROW):
        r = NetSim(topo, routing=pol).run_dag(dag)
        assert r.incomplete == 0, f"{pol}: {r.incomplete} tasks unfinished"
        tput[pol.value] = total / r.makespan_s / 1e9
    derived = {f"{k}_gbs": round(v, 1) for k, v in tput.items()}
    derived["detour_vs_shortest"] = round(tput["detour"] / tput["shortest"], 3)
    derived["borrow_vs_detour"] = round(tput["borrow"] / tput["detour"], 3)
    derived["fig19_ordering"] = (
        tput["shortest"] < tput["detour"] < tput["borrow"]
    )
    ref = {"ordering": "Shortest < Detour < Borrow (Fig. 19)"}
    return derived, ref


def netsim_failure():
    """Mid-collective link failure: all flows still complete via APR."""
    topo = ub_mesh_rack()
    nodes = clique_nodes(topo, 0)
    dag = ring_allreduce(topo, nodes, 64e6)
    sim = NetSim(topo, routing=Routing.DETOUR)
    ok = sim.run_dag(dag)
    bad = sim.run_dag(
        dag, fail_link=(nodes[0], nodes[1]), fail_at_s=ok.makespan_s / 4
    )
    derived = {
        "healthy_ms": round(ok.makespan_s * 1e3, 4),
        "failed_link_ms": round(bad.makespan_s * 1e3, 4),
        "slowdown": round(bad.makespan_s / ok.makespan_s, 3),
        "all_completed": bad.incomplete == 0,
        "notify_hops": bad.failure_stats.get("max_notify_hops", 0),
    }
    ref = {"all_completed": True}
    return derived, ref


def netsim_calibration():
    """Netsim effective-bandwidth calibration for the analytic simulator."""
    from repro.core.perf_model import AnalyticPerfModel

    pod = ub_mesh_pod()
    sim = NetSim(pod, routing=Routing.DETOUR)
    comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
    cal = sim.calibrated_axis_gbs(16e6, comm=comm)
    w, p = moe_2t_workload()
    base = simulate(w, p, comm)
    calibrated = simulate(w, p, AnalyticPerfModel(comm, axis_gbs=cal))
    derived = {f"cal_{k}_gbs": round(v, 1) for k, v in cal.items()}
    derived.update(
        {f"model_{k}_gbs": round(a.gbs_per_chip, 1) for k, a in comm.axes.items()}
    )
    derived["iter_s_analytic"] = round(base.iteration_s, 3)
    derived["iter_s_calibrated"] = round(calibrated.iteration_s, 3)
    ref = {"note": "calibrated <= analytic (contention+schedule effects)"}
    return derived, ref


NETSIM_BENCHMARKS = {
    "netsim_crossval": netsim_crossval,
    "netsim_fig19": netsim_fig19,
    "netsim_failure": netsim_failure,
    "netsim_calibration": netsim_calibration,
}

# the <30s subset for `run.py --suite smoke`
SMOKE_BENCHMARKS = {
    "netsim_crossval": netsim_crossval,
    "netsim_fig19": netsim_fig19,
    "netsim_failure": netsim_failure,
}
