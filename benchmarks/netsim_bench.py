"""Netsim benchmarks: cross-validation against the analytic engine.

Four claims, each one function (same (derived, ref) contract as
``paper_tables.py``):

* **crossval** — on an uncongested single-dimension clique the flow-level
  simulator must reproduce the analytic multi-ring AllReduce time within
  15% (it is the same schedule, executed instead of priced).
* **fig19** — under cross-rack contention the §6.3 routing strategies must
  rank Shortest < Detour < Borrow in delivered throughput (the Fig. 19
  ordering), which only a contention-aware model can show.
* **calibration** — netsim-measured effective axis bandwidths fed back
  into ``core/simulator.simulate`` through the ``PerfModel`` protocol
  (``AnalyticPerfModel`` carrying the measured overrides; the closed-form
  model is optimistic and the calibration quantifies by how much).
* **a2a_crossval** — the collective-shape claim behind the
  ``CalibrationProfile``: A2A-calibrated GB/s < AllReduce-calibrated GB/s
  on the same axis, and the incast-capped MoE dispatch burst strictly
  slower than the incast-blind fluid model says.

``SMOKE_BENCHMARKS`` is the <30 s subset run by ``run.py --suite smoke``.
"""

from __future__ import annotations

from repro.core.cost_model import Routing, build_comm_model
from repro.core.multiring import plan_multiring
from repro.core.simulator import simulate
from repro.core.topology import ub_mesh_pod, ub_mesh_rack
from repro.core.traffic import moe_2t_workload
from repro.netsim import NetSim, hotspot_dag, inter_rack_mesh
from repro.netsim.collectives import (
    clique_nodes,
    model_group,
    moe_dispatch,
    ring_allreduce,
)


# ---------------------------------------------------------------------------
# benchmarks
# ---------------------------------------------------------------------------


def netsim_crossval():
    """Netsim vs analytic multi-ring AllReduce on uncongested cliques."""
    derived = {}
    worst = 0.0
    size = 64e6
    cases = [
        ("rack-X8", ub_mesh_rack(), 0),       # even n=8: zig-zag chains
        ("pod-Z4", ub_mesh_pod(), 2),         # even n=4, inter-rack lanes
    ]
    for label, topo, dim in cases:
        sim = NetSim(topo, routing=Routing.DETOUR)
        t = sim.allreduce_time(dim, size)
        ta = plan_multiring(topo, dim).allreduce_time_s(size)
        rel = abs(t - ta) / ta
        worst = max(worst, rel)
        derived[f"{label}_netsim_ms"] = round(t * 1e3, 4)
        derived[f"{label}_analytic_ms"] = round(ta * 1e3, 4)
        derived[f"{label}_rel_err"] = round(rel, 4)
    derived["within_15pct"] = worst <= 0.15
    ref = {"tolerance": 0.15}
    return derived, ref


def netsim_fig19():
    """Shortest < Detour < Borrow throughput under cross-rack contention."""
    topo = inter_rack_mesh()
    dag = hotspot_dag(topo)
    total = sum(t.size for t in dag.tasks)
    tput = {}
    for pol in (Routing.SHORTEST, Routing.DETOUR, Routing.BORROW):
        r = NetSim(topo, routing=pol).run_dag(dag)
        assert r.incomplete == 0, f"{pol}: {r.incomplete} tasks unfinished"
        tput[pol.value] = total / r.makespan_s / 1e9
    derived = {f"{k}_gbs": round(v, 1) for k, v in tput.items()}
    derived["detour_vs_shortest"] = round(tput["detour"] / tput["shortest"], 3)
    derived["borrow_vs_detour"] = round(tput["borrow"] / tput["detour"], 3)
    derived["fig19_ordering"] = (
        tput["shortest"] < tput["detour"] < tput["borrow"]
    )
    ref = {"ordering": "Shortest < Detour < Borrow (Fig. 19)"}
    return derived, ref


def netsim_failure():
    """Mid-collective link failure: all flows still complete via APR."""
    topo = ub_mesh_rack()
    nodes = clique_nodes(topo, 0)
    dag = ring_allreduce(topo, nodes, 64e6)
    sim = NetSim(topo, routing=Routing.DETOUR)
    ok = sim.run_dag(dag)
    bad = sim.run_dag(
        dag, fail_link=(nodes[0], nodes[1]), fail_at_s=ok.makespan_s / 4
    )
    derived = {
        "healthy_ms": round(ok.makespan_s * 1e3, 4),
        "failed_link_ms": round(bad.makespan_s * 1e3, 4),
        "slowdown": round(bad.makespan_s / ok.makespan_s, 3),
        "all_completed": bad.incomplete == 0,
        "notify_hops": bad.failure_stats.get("max_notify_hops", 0),
    }
    ref = {"all_completed": True}
    return derived, ref


def netsim_calibration():
    """Netsim effective-bandwidth calibration for the analytic simulator."""
    from repro.core.perf_model import AnalyticPerfModel

    pod = ub_mesh_pod()
    sim = NetSim(pod, routing=Routing.DETOUR)
    comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
    cal = sim.calibrated_axis_gbs(16e6, comm=comm)
    w, p = moe_2t_workload()
    base = simulate(w, p, comm)
    calibrated = simulate(w, p, AnalyticPerfModel(comm, axis_gbs=cal))
    derived = {f"cal_{k}_gbs": round(v, 1) for k, v in cal.items()}
    derived.update(
        {f"model_{k}_gbs": round(a.gbs_per_chip, 1) for k, a in comm.axes.items()}
    )
    derived["iter_s_analytic"] = round(base.iteration_s, 3)
    derived["iter_s_calibrated"] = round(calibrated.iteration_s, 3)
    ref = {"note": "calibrated <= analytic (contention+schedule effects)"}
    return derived, ref


def netsim_a2a_crossval():
    """Collective-SHAPE crossval: the A2A-calibrated bandwidth must sit
    strictly below the AllReduce-calibrated one on the model axis (relay
    hops + the cross-board cut), and a many-to-one MoE dispatch burst must
    run strictly slower with receiver-egress (incast) caps than the
    incast-blind fluid model claims."""
    topo = ub_mesh_rack()
    comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
    sim = NetSim(topo, routing=Routing.DETOUR)
    prof = sim.calibrated_profile(
        16e6, comm=comm, axes=("model",), shapes=("allreduce", "all_to_all")
    )
    ar = prof.get("model", "allreduce")
    a2a = prof.get("model", "all_to_all")
    # 64 token-holders dispatching to 4 hot expert chips: the incast burst
    senders = list(range(topo.num_nodes))
    experts = model_group(topo, 4)
    dag = moe_dispatch(topo, senders, experts, 16e6)
    t_incast = NetSim(topo, routing=Routing.DETOUR).run_dag(dag).makespan_s
    t_fluid = NetSim(topo, routing=Routing.DETOUR, rx_gbs=None).run_dag(dag).makespan_s
    derived = {
        "model_allreduce_gbs": round(ar, 1),
        "model_a2a_gbs": round(a2a, 1),
        "a2a_below_allreduce": a2a < ar,
        "dispatch_incast_ms": round(t_incast * 1e3, 4),
        "dispatch_fluid_ms": round(t_fluid * 1e3, 4),
        "incast_slowdown": round(t_incast / t_fluid, 3),
        "incast_strictly_slower": t_incast > t_fluid,
    }
    ref = {"note": "a2a < allreduce on the same axis; incast > fluid"}
    return derived, ref


NETSIM_BENCHMARKS = {
    "netsim_crossval": netsim_crossval,
    "netsim_fig19": netsim_fig19,
    "netsim_failure": netsim_failure,
    "netsim_calibration": netsim_calibration,
    "netsim_a2a_crossval": netsim_a2a_crossval,
}

# the <30s subset for `run.py --suite smoke`
SMOKE_BENCHMARKS = {
    "netsim_crossval": netsim_crossval,
    "netsim_fig19": netsim_fig19,
    "netsim_failure": netsim_failure,
    "netsim_a2a_crossval": netsim_a2a_crossval,
}
