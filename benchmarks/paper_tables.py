"""One benchmark per paper table/figure (UB-Mesh §6).

Each function returns (derived_dict, reference_dict) — computed numbers next
to the paper's published values — and run.py times it and emits CSV.

All pricing goes through the ``core.perf_model.PerfModel`` protocol: the
figure reproductions use the analytic backend (a plain ``CommModel``) to
stay faithful to the paper's idealized cost model, while
``benchmarks/planner_bench.py`` compares it against the netsim-calibrated
backend on the same planner.
"""

from __future__ import annotations

from dataclasses import replace

from repro.core import alltoall, apr, availability, capex, cost_model, multiring
from repro.core import simulator, topology, traffic
from repro.core.cost_model import Routing
from repro.core.planner import best_parallel_spec
from repro.core.traffic import ParallelSpec, WorkloadSpec


# ---------------------------------------------------------------------------
# Table 1 — traffic analysis
# ---------------------------------------------------------------------------


def table1_traffic():
    w, p = traffic.moe_2t_workload()
    tab = traffic.analyze_traffic(w, p)
    derived = {f"{t}_share": round(tab.share(t), 4) for t in ("TP", "SP", "EP", "PP", "DP")}
    derived["local_share"] = round(tab.local_share(), 4)
    ref = {f"{k}_share": v["share"] for k, v in traffic.PAPER_TABLE1.items()}
    return derived, ref


# ---------------------------------------------------------------------------
# Table 2 — link-type usage
# ---------------------------------------------------------------------------


def table2_links():
    sp = topology.SuperPod()
    cb = sp.cables_by_link_type(uplink_provisioning=0.25)
    tot = sum(cb.values())
    derived = {k: round(v / tot, 4) for k, v in cb.items()}
    ref = {
        "passive_electrical": 0.867,
        "active_electrical": 0.072,
        "optical_100m": 0.048,
        "optical_1km": 0.012,
    }
    return derived, ref


# ---------------------------------------------------------------------------
# Fig. 17 — intra-rack architecture comparison (8K SuperPod)
# ---------------------------------------------------------------------------

_MODELS = {
    "LLAMA2-70B": WorkloadSpec("LLAMA2-70B", 80, 8192, 64, 128, 8,
                               seq_len=32768, global_batch=512, params_total=7e10),
    "GPT3-175B": WorkloadSpec("GPT3-175B", 96, 12288, 96, 128, 8,
                              seq_len=32768, global_batch=512, params_total=175e9),
    "Dense-1T": WorkloadSpec("Dense-1T", 128, 24576, 128, 192, 8,
                             seq_len=32768, global_batch=512, params_total=1e12),
    "GPT4-2T": WorkloadSpec("GPT4-2T", 96, 12288, 96, 128, 8,
                            seq_len=32768, global_batch=512, params_total=2e12,
                            n_experts=16, topk=2),
    "MoE-10T": WorkloadSpec("MoE-10T", 128, 18432, 144, 128, 8,
                            seq_len=32768, global_batch=512, params_total=1e13,
                            n_experts=32, topk=2, moe_param_frac=0.9),
}


# paper-faithful fixed parallelizations (the paper compares topologies at a
# FIXED parallelization; letting the planner re-optimize per variant hides
# the topology effect)
_FIXED_SPEC = {
    "LLAMA2-70B": ParallelSpec(tp=8, sp=8, pp=4, dp=256, microbatches=16),
    "GPT3-175B": ParallelSpec(tp=8, sp=8, pp=8, dp=128, microbatches=16),
    "Dense-1T": ParallelSpec(tp=8, sp=8, pp=16, dp=64, microbatches=32),
    "GPT4-2T": ParallelSpec(tp=8, sp=8, pp=16, dp=64, ep=8, microbatches=32),
    "MoE-10T": ParallelSpec(tp=8, sp=8, pp=32, dp=32, ep=16, microbatches=32),
}


def _throughput(w, perf, chips=8192, planned=False):
    """Tokens/s for workload ``w`` under any PerfModel backend ``perf``."""
    if planned or w.name not in _FIXED_SPEC:
        spec = best_parallel_spec(w, chips, perf)
    else:
        spec = _FIXED_SPEC[w.name]
    return simulator.simulate(w, spec, perf).tokens_per_s


def fig17_intra_rack():
    derived = {}
    for name, w in _MODELS.items():
        clos = _throughput(w, simulator.intra_rack_comm_model("Clos"))
        for variant in ("2D-FM", "1D-FM-A", "1D-FM-B"):
            tput = _throughput(w, simulator.intra_rack_comm_model(variant))
            derived[f"{name}/{variant}"] = round(tput / clos, 4)
    # the paper averages sequence lengths up to 10M, where TP*SP spills
    # beyond the rack (the regime that opens the 4-7% gap); one long-seq
    # point makes that regime visible
    w_long = replace(_MODELS["GPT4-2T"], seq_len=524288, global_batch=64)
    spec = ParallelSpec(tp=8, sp=32, pp=16, dp=4, ep=8, microbatches=16)
    t_clos = simulator.simulate(
        w_long, spec, simulator.intra_rack_comm_model("Clos")
    ).tokens_per_s
    t_fm = simulator.simulate(
        w_long, spec, simulator.intra_rack_comm_model("2D-FM")
    ).tokens_per_s
    derived["GPT4-2T-seq512K/2D-FM"] = round(t_fm / t_clos, 4)
    ref = {"2D-FM_vs_Clos": "0.932..0.959 (paper, seq 8K..10M avg)"}
    return derived, ref


# ---------------------------------------------------------------------------
# Fig. 19 — inter-rack routing strategies
# ---------------------------------------------------------------------------


def fig19_inter_rack():
    derived = {}
    for name in ("GPT3-175B", "GPT4-2T"):
        w = _MODELS[name]
        clos = _throughput(w, simulator.inter_rack_comm_model("Clos"))
        for strat in ("Shortest", "Detour", "Borrow"):
            t = _throughput(w, simulator.inter_rack_comm_model(strat))
            derived[f"{name}/{strat}"] = round(t / clos, 4)
    ref = {
        "GPT4-2T/Shortest": 1 - 0.0073,
        "GPT4-2T/Detour+Borrow": 1 - 0.0046,
    }
    return derived, ref


# ---------------------------------------------------------------------------
# Fig. 20 — inter-rack bandwidth sweep
# ---------------------------------------------------------------------------


def fig20_bandwidth():
    derived = {}
    for seq, label in ((16384, "seq8-32K"), (262144, "seq64K-10M")):
        w = replace(_MODELS["GPT3-175B"], seq_len=seq,
                    global_batch=max(64, 2048 * 8192 // seq))
        base = None
        for lanes in (4, 8, 16, 32):
            comm = cost_model.build_comm_model(
                multi_pod=True, routing=Routing.DETOUR, inter_rack_lanes=lanes
            )
            t = _throughput(w, comm, planned=True)
            if base is None:
                base = t
            derived[f"{label}/x{lanes}"] = round(t / base, 4)
    ref = {"optimal8-32K": "x16", "optimal64K-10M": "x32 (+1.85% over x16)"}
    return derived, ref


# ---------------------------------------------------------------------------
# Fig. 21 — CapEx + cost-efficiency
# ---------------------------------------------------------------------------


def fig21_capex():
    rows = capex.compare_architectures(8192)
    ub = next(r for r in rows if "UB-Mesh" in r.name)
    clos = next(r for r in rows if "x64T" in r.name)
    ub_bom = capex.ub_mesh_bom(8192)
    derived = {
        "capex_ratio_clos_vs_ubmesh": round(clos.capex / ub.capex, 3),
        "network_share_ubmesh": round(ub_bom.network_share(), 3),
        "network_share_clos": round(capex.clos_bom(8192).network_share(), 3),
        "cost_efficiency_gain": round(
            ub.cost_efficiency / clos.cost_efficiency, 3
        ),
        "opex_reduction": round(1 - ub.opex / clos.opex, 3),
    }
    for r in rows:
        derived[f"capex[{r.name}]"] = round(r.capex / ub.capex, 3)
    ref = {
        "capex_ratio_clos_vs_ubmesh": 2.46,
        "network_share_ubmesh": 0.20,
        "network_share_clos": 0.67,
        "cost_efficiency_gain": 2.04,
        "opex_reduction": 0.35,
    }
    return derived, ref


# ---------------------------------------------------------------------------
# Fig. 22 — linearity
# ---------------------------------------------------------------------------


def fig22_linearity():
    derived = {}
    cases = {
        "LLAMA2-70B": (replace(_MODELS["LLAMA2-70B"], seq_len=262144, global_batch=16), 128),
        "GPT3-175B": (replace(_MODELS["GPT3-175B"], seq_len=262144, global_batch=64), 512),
        "GPT4-2T": (replace(_MODELS["GPT4-2T"], seq_len=262144, global_batch=64), 1024),
    }
    for name, (w, base) in cases.items():
        lin = simulator.linearity_curve(w, base, [1, 4, 16, 64])
        for k, v in lin.items():
            derived[f"{name}/x{k}"] = round(v, 4)
    ref = {"all@64x": ">= 0.95 (paper)"}
    return derived, ref


# ---------------------------------------------------------------------------
# Table 6 — MTBF / availability
# ---------------------------------------------------------------------------


def table6_mtbf():
    ub, clos = availability.PAPER_UB_MESH, availability.PAPER_CLOS
    ub_d, clos_d = availability.derived_afr(8192)
    derived = {
        "ubmesh_mtbf_h": round(ub.mtbf_hours, 1),
        "clos_mtbf_h": round(clos.mtbf_hours, 1),
        "mtbf_gain": round(ub.mtbf_hours / clos.mtbf_hours, 2),
        "ubmesh_avail": round(ub.availability(availability.PAPER_MTTR_HOURS), 4),
        "clos_avail": round(clos.availability(availability.PAPER_MTTR_HOURS), 4),
        "ubmesh_avail_fast_mttr": round(
            ub.availability(availability.FAST_MTTR_HOURS), 4
        ),
        "derived_ubmesh_afr": round(ub_d.total, 1),
        "derived_clos_afr": round(clos_d.total, 1),
    }
    ref = {
        "ubmesh_mtbf_h": 98.5,
        "clos_mtbf_h": 13.8,
        "mtbf_gain": 7.14,
        "ubmesh_avail": 0.988,
        "clos_avail": 0.916,
        "ubmesh_avail_fast_mttr": 0.9978,
    }
    return derived, ref


# ---------------------------------------------------------------------------
# §3.3.2 — 64+1 backup analysis (supplementary)
# ---------------------------------------------------------------------------


def backup_64plus1():
    b = availability.BackupAnalysis()
    derived = {
        "capacity_loss_improvement": round(b.capacity_loss_improvement(), 1),
        "redirect_extra_hops": b.redirected_path_penalty_hops(),
    }
    ref = {"redirect_extra_hops": 1}
    return derived, ref


ALL_BENCHMARKS = {
    "table1_traffic": table1_traffic,
    "table2_links": table2_links,
    "fig17_intra_rack": fig17_intra_rack,
    "fig19_inter_rack": fig19_inter_rack,
    "fig20_bandwidth": fig20_bandwidth,
    "fig21_capex": fig21_capex,
    "fig22_linearity": fig22_linearity,
    "table6_mtbf": table6_mtbf,
    "backup_64plus1": backup_64plus1,
}
