"""Planner-backend comparison: analytic vs netsim-calibrated spec rankings.

One benchmark, two configs (a dense model and an MoE), same contract as
``paper_tables.py`` — returns (derived, ref) and ``run.py`` times it.  The
point is the tentpole claim of the PerfModel refactor: the §5.2 planner can
rank candidate parallelizations on *measured* flow-level bandwidths instead
of the closed-form idealized ones, and the two backends genuinely disagree
where contention matters (narrow TP*SP groups cannot ride the cross-dim 2D
multi-ring, so the netsim backend prices them far below the analytic
model's flat 200 GB/s model axis).

Budget: < 10 s.  The netsim backend memoizes calibration per unique
(axis, group-width, routing) key, so the second config reuses nearly every
measurement of the first.
"""

from __future__ import annotations

from repro.core.cost_model import Routing, build_comm_model
from repro.core.perf_model import AnalyticPerfModel, NetsimPerfModel
from repro.core.planner import plan
from repro.core.topology import ub_mesh_pod
from repro.core.traffic import backend_comparison_workloads

# calibration payload small enough to keep the whole comparison in budget;
# the effective-bandwidth *ordering* (wide grid > narrow hierarchical) is
# size-independent, only the latency overhead fraction changes
_CAL_BYTES = 64e6

# the canonical (uncongested -> agree, contended -> diverge) pair; see the
# helper's docstring for why the MoE config flips the winner
_CONFIGS = {w.name: w for w in backend_comparison_workloads()}


def planner_backends():
    comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
    analytic = AnalyticPerfModel(comm)
    netsim = NetsimPerfModel(comm, topo=ub_mesh_pod(), size_bytes=_CAL_BYTES)
    derived = {}
    for name, w in _CONFIGS.items():
        ra = plan(w, 256, analytic, top_k=3)
        rn = plan(w, 256, netsim, top_k=3)
        sa, sn = ra[0].spec, rn[0].spec
        derived[f"{name}/analytic"] = (
            f"tp{sa.tp}.sp{sa.sp}.pp{sa.pp}.dp{sa.dp}.ep{sa.ep}"
        )
        derived[f"{name}/netsim"] = (
            f"tp{sn.tp}.sp{sn.sp}.pp{sn.pp}.dp{sn.dp}.ep{sn.ep}"
        )
        derived[f"{name}/agree"] = sa == sn
        derived[f"{name}/iter_s_analytic"] = round(ra[0].iteration_s, 3)
        derived[f"{name}/iter_s_netsim"] = round(rn[0].iteration_s, 3)
        derived[f"{name}/skipped"] = rn.n_skipped
    cm = netsim.comm_model(None)
    derived["cal_model_gbs_fullplane"] = round(cm.axes["model"].gbs_per_chip, 1)
    derived["cal_data_gbs"] = round(cm.axes["data"].gbs_per_chip, 1)
    ref = {
        "note": "netsim iter >= analytic iter (measured bw <= idealized)",
        "analytic_model_gbs": round(comm.axes["model"].gbs_per_chip, 1),
    }
    return derived, ref


PLANNER_BENCHMARKS = {"planner_backends": planner_backends}
