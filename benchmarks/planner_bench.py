"""Planner-backend comparison: analytic vs netsim-calibrated spec rankings.

One benchmark, three configs, same contract as ``paper_tables.py`` —
returns (derived, ref) and ``run.py`` times it.  Two claims:

* **PR 2 (scalar calibration)**: the §5.2 planner can rank candidate
  parallelizations on *measured* flow-level bandwidths instead of the
  closed-form idealized ones, and the backends genuinely disagree where
  contention matters (narrow TP*SP groups cannot ride the cross-dim 2D
  multi-ring) — the ``clean`` / ``contended`` pair.
* **PR 3 (collective-shape profile)**: pricing every collective off one
  AllReduce-calibrated scalar systematically flatters expert parallelism.
  The ``divergence`` MoE config is ranked by two *netsim* backends that
  differ only in shape awareness — the AllReduce-proxy one
  (``shapes=("allreduce",)``) maxes out EP, the full
  ``CalibrationProfile`` one prices the dispatch A2A on its measured
  bandwidth (relay hops + incast, ~3x below AllReduce on the cross-board
  axis) and retreats to clique-local EP — so the winning ``ParallelSpec``
  flips on A2A pricing alone.

Budget: < 10 s.  Calibration is memoized per unique (axis, shape,
group-width, routing) key, so the three configs share nearly every
measurement; the payload is kept small enough that the full-plane grid
runs (the dominant cost) stay in budget — the bandwidth *ordering*
(wide grid > narrow hierarchical, ring > cross-board A2A) is
size-independent, only the latency overhead fraction changes.
"""

from __future__ import annotations

from repro.core.cost_model import Routing, build_comm_model
from repro.core.perf_model import AnalyticPerfModel, NetsimPerfModel
from repro.core.planner import plan
from repro.core.topology import ub_mesh_pod
from repro.core.traffic import (
    a2a_divergence_workload,
    backend_comparison_workloads,
)

_CAL_BYTES = 16e6

# the canonical (uncongested -> agree, contended -> diverge) pair; see the
# helper's docstring for why the MoE config flips the winner
_CONFIGS = {w.name: w for w in backend_comparison_workloads()}


def _fmt(s) -> str:
    return f"tp{s.tp}.sp{s.sp}.pp{s.pp}.dp{s.dp}.ep{s.ep}"


def planner_backends():
    comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
    analytic = AnalyticPerfModel(comm)
    netsim = NetsimPerfModel(comm, topo=ub_mesh_pod(), size_bytes=_CAL_BYTES)
    derived = {}
    for name, w in _CONFIGS.items():
        ra = plan(w, 256, analytic, top_k=3)
        rn = plan(w, 256, netsim, top_k=3)
        sa, sn = ra[0].spec, rn[0].spec
        derived[f"{name}/analytic"] = _fmt(sa)
        derived[f"{name}/netsim"] = _fmt(sn)
        derived[f"{name}/agree"] = sa == sn
        derived[f"{name}/iter_s_analytic"] = round(ra[0].iteration_s, 3)
        derived[f"{name}/iter_s_netsim"] = round(rn[0].iteration_s, 3)
        derived[f"{name}/skipped"] = rn.n_skipped
        # per-exception attribution: a nonzero bucket here names the
        # simulate() failure mode instead of hiding it in one total
        for exc, n in sorted(rn.skipped.items()):
            derived[f"{name}/skipped:{exc}"] = n
        derived[f"{name}/plan_wall_s"] = round(rn.wall_s, 3)
        derived[f"{name}/specs_per_s"] = (
            round(rn.n_enumerated / rn.wall_s, 1) if rn.wall_s > 0 else 0.0
        )
        derived[f"{name}/n_prefiltered"] = rn.n_prefiltered
        cal = rn.calibration
        derived[f"{name}/cal_hits"] = cal.get("hits", 0)
        derived[f"{name}/cal_misses"] = cal.get("misses", 0)
        derived[f"{name}/cal_disk_hits"] = cal.get("disk_hits", 0)
        derived[f"{name}/cal_measure_s"] = round(cal.get("measure_s", 0.0), 3)
        # sweep-level batching stats: how many solver sessions the keys
        # were packed into (keys/session > 1 means sharing happened)
        sessions = cal.get("sessions", 0)
        keys = cal.get("session_keys", 0)
        derived[f"{name}/cal_sessions"] = sessions
        derived[f"{name}/cal_session_keys"] = keys
        derived[f"{name}/cal_keys_per_session"] = (
            round(keys / sessions, 2) if sessions else 0.0
        )
    # shape-awareness flip: same netsim backend, AllReduce proxy vs profile
    proxy = NetsimPerfModel(
        comm, topo=ub_mesh_pod(), size_bytes=_CAL_BYTES, shapes=("allreduce",)
    )
    w = a2a_divergence_workload()
    rp = plan(w, 256, proxy, top_k=3)
    rn = plan(w, 256, netsim, top_k=3)
    derived[f"{w.name}/allreduce_proxy"] = _fmt(rp[0].spec)
    derived[f"{w.name}/a2a_profile"] = _fmt(rn[0].spec)
    derived[f"{w.name}/flips_on_a2a_pricing"] = rp[0].spec != rn[0].spec
    cm = netsim.comm_model(None)
    a = cm.axes["model"]
    derived["cal_model_gbs_fullplane"] = round(a.gbs_per_chip, 1)
    derived["cal_model_a2a_gbs"] = round(a.bw_for("all_to_all"), 1)
    derived["a2a_below_allreduce"] = (
        a.bw_for("all_to_all") < a.bw_for("allreduce")
    )
    derived["cal_data_gbs"] = round(cm.axes["data"].gbs_per_chip, 1)
    ref = {
        "note": "netsim iter >= analytic iter (measured bw <= idealized)",
        "analytic_model_gbs": round(comm.axes["model"].gbs_per_chip, 1),
    }
    return derived, ref


PLANNER_BENCHMARKS = {"planner_backends": planner_backends}
