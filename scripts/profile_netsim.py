"""Profiling harness for netsim perf work: a pod-level calibration run.

    PYTHONPATH=src python scripts/profile_netsim.py
    PYTHONPATH=src python scripts/profile_netsim.py --solver reference --no-aggregate
    PYTHONPATH=src python scripts/profile_netsim.py --top 20 --size-bytes 64e6

Times ``NetSim.calibrated_axis_gbs`` on the 1024-chip UB-Mesh pod (the
benchmark the ISSUE-4 speedup targets are measured on) and prints the
top-N cumulative cProfile hotspots, so future perf PRs have a baseline
command: run it before and after, compare the wall time and the table.
"""

from __future__ import annotations

import argparse
import cProfile
import pstats
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--solver",
        choices=("vectorized", "reference"),
        default="vectorized",
        help="max-min solver backend (netsim/solver.py)",
    )
    ap.add_argument(
        "--no-aggregate",
        action="store_true",
        help="expand multi-ring steps into per-pair flows (the pre-ISSUE-4 "
        "execution mode)",
    )
    ap.add_argument("--size-bytes", type=float, default=16e6)
    ap.add_argument("--top", type=int, default=10, help="hotspots to print")
    ap.add_argument(
        "--sort", default="cumulative", help="pstats sort key (cumulative/tottime)"
    )
    args = ap.parse_args()

    from repro.core.cost_model import Routing, build_comm_model
    from repro.core.topology import ub_mesh_pod
    from repro.netsim import NetSim

    comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
    sim = NetSim(
        ub_mesh_pod(),
        routing=Routing.DETOUR,
        solver=args.solver,
        aggregate=not args.no_aggregate,
    )
    # untimed warm-up so one-time costs (path caches, coords memo) don't
    # pollute the profile of the steady state
    sim.calibrated_axis_gbs(1e6, comm=comm)

    prof = cProfile.Profile()
    t0 = time.perf_counter()
    prof.enable()
    cal = sim.calibrated_axis_gbs(args.size_bytes, comm=comm)
    prof.disable()
    wall = time.perf_counter() - t0

    print(
        f"pod calibrated_axis_gbs(size={args.size_bytes:.0e}, "
        f"solver={args.solver}, aggregate={not args.no_aggregate}): "
        f"{wall:.3f} s wall"
    )
    for axis, gbs in sorted(cal.items()):
        print(f"  {axis}: {gbs:.1f} GB/s")
    print(f"\ntop {args.top} by {args.sort}:")
    pstats.Stats(prof).sort_stats(args.sort).print_stats(args.top)


if __name__ == "__main__":
    main()
