"""Regenerate the roofline summary + append the markdown table.

    python scripts/finalize_roofline.py [--root PATH]

Reads the dry-run cell records under ``<root>/results/dryrun/``, prints the
ok/skipped/error + bottleneck summary, and rewrites
``<root>/results/roofline_table.md`` from ``benchmarks.roofline --markdown``.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--root",
        type=pathlib.Path,
        default=pathlib.Path(__file__).resolve().parent.parent,
        help="repo root (default: this script's parent repo)",
    )
    args = ap.parse_args()
    root = args.root

    recs = [
        json.loads(f.read_text())
        for f in sorted((root / "results" / "dryrun").glob("*.json"))
    ]
    ok = [r for r in recs if r["status"] == "ok"]
    skipped = [r for r in recs if r["status"] == "skipped"]
    err = [r for r in recs if r["status"] == "error"]
    bn: dict[str, int] = {}
    for r in ok:
        bn[r["roofline"]["bottleneck"]] = bn.get(r["roofline"]["bottleneck"], 0) + 1
    print(f"cells: ok={len(ok)} skipped={len(skipped)} error={len(err)}")
    print("bottlenecks:", bn)

    def frac(r: dict) -> float:
        rl = r["roofline"]
        return rl["compute_s"] / max(
            rl["compute_s"], rl["memory_s"], rl["collective_s"]
        )

    ok_sorted = sorted(ok, key=frac)
    print(
        "worst roofline fraction:",
        [(r["arch"], r["shape"], r["mesh"], round(frac(r), 3)) for r in ok_sorted[:3]],
    )
    print(
        "best roofline fraction:",
        [(r["arch"], r["shape"], r["mesh"], round(frac(r), 3)) for r in ok_sorted[-3:]],
    )

    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.roofline", "--markdown"],
        capture_output=True,
        text=True,
        env=env,
        cwd=str(root),
    )
    if out.returncode != 0:
        sys.stderr.write(out.stderr)
        sys.exit(f"benchmarks.roofline failed (exit {out.returncode}); "
                 "results/roofline_table.md left untouched")
    (root / "results" / "roofline_table.md").write_text(out.stdout)
    print("table written to results/roofline_table.md")


if __name__ == "__main__":
    main()
