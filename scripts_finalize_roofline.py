"""Regenerate the EXPERIMENTS.md roofline summary + append the markdown table."""
import json, pathlib, subprocess, sys

root = pathlib.Path("/root/repo")
recs = [json.loads(f.read_text()) for f in sorted((root/"results/dryrun").glob("*.json"))]
ok = [r for r in recs if r["status"] == "ok"]
skipped = [r for r in recs if r["status"] == "skipped"]
err = [r for r in recs if r["status"] == "error"]
bn = {}
for r in ok:
    bn[r["roofline"]["bottleneck"]] = bn.get(r["roofline"]["bottleneck"], 0) + 1
print(f"cells: ok={len(ok)} skipped={len(skipped)} error={len(err)}")
print("bottlenecks:", bn)
frac = lambda r: (r["roofline"]["compute_s"] / max(r["roofline"]["compute_s"], r["roofline"]["memory_s"], r["roofline"]["collective_s"]))
ok_sorted = sorted(ok, key=frac)
print("worst roofline fraction:", [(r['arch'], r['shape'], r['mesh'], round(frac(r),3)) for r in ok_sorted[:3]])
print("best roofline fraction:", [(r['arch'], r['shape'], r['mesh'], round(frac(r),3)) for r in ok_sorted[-3:]])
# append markdown table to a file
out = subprocess.run([sys.executable, "-m", "benchmarks.roofline", "--markdown"],
                     capture_output=True, text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}, cwd=str(root))
(root/"results/roofline_table.md").write_text(out.stdout)
print("table written to results/roofline_table.md")
